"""Mixture-of-Experts with an explicit shard_map schedule.

Unified capacity-buffer dispatch (GShard-style dropping), two weight
layouts chosen automatically by divisibility against the ``model`` axis:

  * EP  (n_experts % model_size == 0, e.g. deepseek 256, jamba 16):
    experts sharded over ``model``; every model-shard holds the full
    (replicated) activations, dispatches only the tokens routed to its
    local experts into an (E_local, C, D) buffer, runs dense per-expert
    matmuls (MXU-shaped), and the partial outputs are psum'd over
    ``model``.  Compute per shard = 1/model_size of the MoE FLOPs; the
    only collective is the same (T, D) psum a tensor-parallel MLP pays.

  * TP  (small expert counts, e.g. mixtral 8): all experts local, the
    d_expert dim sharded over ``model``; same buffer, same psum.

Outside a mesh (CPU tests) the same local function runs unsharded.

The router aux (Switch load-balance loss) is pmean'd across shards.
ep_mode="a2a" (hillclimb target) replaces the replicated-activation
dispatch with a true all-to-all token exchange — see §Perf.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.layers import PAb


def moe_ab(cfg: ArchConfig):
    d = cfg.d_model
    m = cfg.moe
    s = d ** -0.5
    p = {
        "router": PAb((d, m.n_experts), ("embed", None), "normal", s),
        "up": PAb((m.n_experts, d, m.d_expert), ("experts", "embed", "mlp"),
                  "normal", s),
        "gate": PAb((m.n_experts, d, m.d_expert), ("experts", "embed", "mlp"),
                    "normal", s),
        "down": PAb((m.n_experts, m.d_expert, d), ("experts", "mlp", "embed"),
                    "normal", m.d_expert ** -0.5),
    }
    if m.n_shared:
        p["shared"] = L.mlp_ab(d, m.d_expert * m.n_shared, gated=cfg.gated)
    return p


def _capacity(cfg, T):
    m = cfg.moe
    return max(int(math.ceil(T * m.top_k * m.capacity_factor / m.n_experts)),
               min(8, T))


def _router(cfg, router_w, x):
    """x: (T, D) -> (weights (T,k), ids (T,k), aux_loss)."""
    m = cfg.moe
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    if m.router_scale:
        weights = weights / jnp.maximum(
            jnp.sum(weights, -1, keepdims=True), 1e-9)
    T = x.shape[0]
    f = jnp.zeros(m.n_experts, jnp.float32).at[ids.reshape(-1)].add(1.0) \
        / (T * m.top_k)
    pbar = jnp.mean(probs, axis=0).astype(jnp.float32)
    aux = (m.n_experts * jnp.sum(f * pbar)).astype(jnp.float32)
    return weights.astype(x.dtype), ids, aux


def _dispatch_indices(cfg, ids, T, C, e_start, e_count):
    """Slot bookkeeping for the capacity buffer of local experts
    [e_start, e_start+e_count).  Returns (tok_idx, local_eid, slot, keep)
    all shaped (T*top_k,)."""
    m = cfg.moe
    flat_ids = ids.reshape(-1)                        # (T*k,) global expert
    local = jnp.logical_and(flat_ids >= e_start, flat_ids < e_start + e_count)
    local_eid = jnp.where(local, flat_ids - e_start, e_count)  # e_count=trash
    # position within each expert's queue, computed in (token,slot) order
    onehot = jax.nn.one_hot(local_eid, e_count + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                       # (T*k, E+1)
    slot = jnp.take_along_axis(pos, local_eid[:, None], axis=1)[:, 0]
    keep = jnp.logical_and(local, slot < C)
    tok_idx = jnp.arange(flat_ids.shape[0]) // m.top_k
    return tok_idx, local_eid, slot, keep


def _expert_ffn(cfg, up, gate, down, xe):
    """xe: (E_loc, C, D) -> (E_loc, C, D); dense per-expert matmuls."""
    actf = jax.nn.silu if cfg.act == "silu" else (
        lambda z: jax.nn.gelu(z, approximate=True))
    h = jnp.einsum("ecd,edf->ecf", xe, up.astype(xe.dtype))
    if cfg.gated:
        h = actf(jnp.einsum("ecd,edf->ecf", xe, gate.astype(xe.dtype))) * h
    else:
        h = actf(h)
    return jnp.einsum("ecf,efd->ecd", h, down.astype(xe.dtype))


def _local_moe(cfg, x, router_w, up, gate, down, e_start, n_local, C,
               model_axis=None, batch_axes=()):
    """Per-shard MoE: x (T,D) local tokens, experts [e_start, +n_local)."""
    T, D = x.shape
    weights, ids, aux = _router(cfg, router_w, x)
    tok_idx, local_eid, slot, keep = _dispatch_indices(
        cfg, ids, T, C, e_start, n_local)

    safe_e = jnp.minimum(local_eid, n_local - 1)
    safe_s = jnp.minimum(slot, C - 1)
    xe = jnp.zeros((n_local, C, D), x.dtype)
    gathered = x[tok_idx] * keep[:, None].astype(x.dtype)
    xe = xe.at[safe_e, safe_s].add(jnp.where(keep[:, None], gathered, 0.0))

    ye = _expert_ffn(cfg, up, gate, down, xe)

    w_flat = weights.reshape(-1)
    contrib = ye[safe_e, safe_s] * (w_flat * keep.astype(w_flat.dtype))[:, None]
    y = jnp.zeros_like(x).at[tok_idx].add(contrib)

    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    for ax in batch_axes:
        aux = jax.lax.pmean(aux, ax)
    if model_axis is not None:
        aux = jax.lax.pmean(aux, model_axis)
    return y, aux


def moe_block(cfg: ArchConfig, params, x, mesh=None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (B,S,D)."""
    m = cfg.moe
    B, S, D = x.shape

    if mesh is None or "model" not in mesh.axis_names:
        xt = x.reshape(B * S, D)
        C = _capacity(cfg, B * S)
        y, aux = _local_moe(cfg, xt, params["router"], params["up"],
                            params["gate"], params["down"],
                            e_start=0, n_local=m.n_experts, C=C)
        if m.n_shared:
            y = y + L.mlp(params["shared"], xt, cfg.act, cfg.gated)
        return y.reshape(B, S, D), aux

    model_n = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_div = math.prod(mesh.shape[a] for a in batch_axes) or 1
    div_ok = B % batch_div == 0
    if not div_ok:      # e.g. batch=1 long-context decode: replicate
        batch_axes = ()
    B_local = B // batch_div if div_ok else B
    T_local = B_local * S
    ep = m.n_experts % model_n == 0 and m.n_experts >= model_n
    n_local = m.n_experts // model_n if ep else m.n_experts
    C = _capacity(cfg, T_local)

    # a2a-EP (§Perf E3b): with the residual stream sequence-sharded over
    # ``model``, dispatch routed token copies to their expert's shard by
    # all_to_all instead of replicating x and psumming partial outputs.
    # Wire per layer drops from AG(x)+AR(y) [~3x activation bytes] to
    # 2 x routed-copy bytes; no collective touches unrouted tokens.
    if ep and S % model_n == 0 and S > 1:
        return _a2a_moe_block(cfg, params, x, mesh, model_n, batch_axes,
                              B_local, n_local)

    batch_p = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    x_spec = P(batch_p, None, None)
    if ep:
        w_spec = P("model", None, None)
    else:
        w_spec = P(None, None, "model")
    down_spec = P("model", None, None) if ep else P(None, "model", None)

    # shared experts ride inside the shard region, tensor-sharded on
    # d_expert, so their partial output folds into the SAME psum as the
    # routed experts (§Perf E3a: one collective per MoE layer, not two)
    shared = params.get("shared")

    def shard_fn(x_l, router_w, up, gate, down, *shared_w):
        T = x_l.shape[0] * x_l.shape[1]
        xt = x_l.reshape(T, D)
        if ep:
            e_start = jax.lax.axis_index("model") * n_local
        else:
            e_start = 0
        y, aux = _local_moe(cfg, xt, router_w, up, gate, down,
                            e_start=e_start, n_local=n_local, C=C,
                            model_axis=None, batch_axes=batch_axes)
        if shared_w:
            sp = dict(zip(sorted(shared), shared_w))
            y = y + L.mlp(sp, xt, cfg.act, cfg.gated)
        y = jax.lax.psum(y, "model")
        return y.reshape(x_l.shape), jax.lax.pmean(aux, "model")

    shared_args, shared_specs = (), ()
    if shared is not None:
        names = sorted(shared)          # down, gate?, up
        shared_args = tuple(shared[k] for k in names)
        shared_specs = tuple(P("model", None) if k == "down"
                             else P(None, "model") for k in names)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, down_spec)
        + shared_specs,
        out_specs=(x_spec, P()),
        check_vma=False)
    y, aux = fn(x, params["router"], params["up"], params["gate"],
                params["down"], *shared_args)
    return y, aux


def _a2a_moe_block(cfg, params, x, mesh, model_n, batch_axes, B_local,
                   n_local):
    """Expert parallelism with all_to_all dispatch over seq-sharded x.

    Per shard: T = B_local * S/model_n local tokens.  Stage 1 buckets
    each (token, slot) by destination shard (cap_out per peer); a2a
    ships the buckets.  Stage 2 buckets arrivals by local expert
    (capacity C2), runs the dense per-expert FFN, and the results take
    the reverse trip.  The shared expert (deepseek) runs locally on the
    seq shard with replicated weights — zero collectives."""
    m = cfg.moe
    B, S, D = x.shape
    T = B_local * (S // model_n)
    cap_out = max(int(math.ceil(T * m.top_k * m.capacity_factor / model_n)),
                  min(8, T * m.top_k))
    C2 = max(int(math.ceil(cap_out * model_n * m.capacity_factor
                           * 1.0 / n_local)), 8)

    batch_p = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    x_spec = P(batch_p, "model", None)
    w_spec = P("model", None, None)

    shared = params.get("shared")
    shared_names = sorted(shared) if shared is not None else []
    shared_args = tuple(shared[k] for k in shared_names)
    shared_specs = tuple(P(None, None) for _ in shared_names)

    def shard_fn(x_l, router_w, up, gate, down, *shared_w):
        xt = x_l.reshape(T, D)
        weights, ids, aux = _router(cfg, router_w, xt)

        # ---- stage 1: bucket by destination shard
        flat_ids = ids.reshape(-1)                     # (T*k,) global expert
        dest = flat_ids // n_local                     # destination shard
        onehot = jax.nn.one_hot(dest, model_n, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        slot = jnp.take_along_axis(pos, dest[:, None], axis=1)[:, 0]
        keep = slot < cap_out
        tok_idx = jnp.arange(flat_ids.shape[0]) // m.top_k
        sd = jnp.minimum(slot, cap_out - 1)

        send = jnp.zeros((model_n, cap_out, D), xt.dtype)
        send = send.at[dest, sd].add(
            jnp.where(keep[:, None], xt[tok_idx], 0.0))
        # metadata: local expert id (+1, 0 = empty) rides along
        meta = jnp.zeros((model_n, cap_out), jnp.int32)
        meta = meta.at[dest, sd].max(
            jnp.where(keep, (flat_ids % n_local) + 1, 0))

        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=False)
        meta_r = jax.lax.all_to_all(meta[..., None], "model", 0, 0,
                                    tiled=False)[..., 0]

        # ---- stage 2: bucket arrivals by local expert
        arr = recv.reshape(model_n * cap_out, D)
        eid = meta_r.reshape(-1)                       # 0 = empty slot
        e1 = jnp.where(eid > 0, eid - 1, n_local)      # trash lane n_local
        oh2 = jax.nn.one_hot(e1, n_local + 1, dtype=jnp.int32)
        pos2 = jnp.cumsum(oh2, axis=0) - 1
        slot2 = jnp.take_along_axis(pos2, e1[:, None], axis=1)[:, 0]
        keep2 = jnp.logical_and(eid > 0, slot2 < C2)
        se = jnp.minimum(e1, n_local - 1)
        ss = jnp.minimum(slot2, C2 - 1)
        xe = jnp.zeros((n_local, C2, D), xt.dtype)
        xe = xe.at[se, ss].add(jnp.where(keep2[:, None], arr, 0.0))

        ye = _expert_ffn(cfg, up, gate, down, xe)

        back = jnp.where(keep2[:, None], ye[se, ss], 0.0) \
            .reshape(model_n, cap_out, D)
        ret = jax.lax.all_to_all(back, "model", 0, 0, tiled=False)

        # ---- combine on the source shard
        w_flat = weights.reshape(-1)
        contrib = ret[dest, sd] * (
            w_flat * keep.astype(w_flat.dtype))[:, None]
        y = jnp.zeros_like(xt).at[tok_idx].add(contrib)

        if shared_w:
            sp = dict(zip(shared_names, shared_w))
            y = y + L.mlp(sp, xt, cfg.act, cfg.gated)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        aux = jax.lax.pmean(aux, "model")
        return y.reshape(x_l.shape), aux

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec)
        + shared_specs,
        out_specs=(x_spec, P()),
        check_vma=False)
    return fn(x, params["router"], params["up"], params["gate"],
              params["down"], *shared_args)
