"""Attention variants: GQA/MQA (RoPE, optional sliding window), and
DeepSeek-style MLA (multi-head latent attention) with an absorbed
latent-cache decode path.

Decode KV caches are sequence-sharded over the ``model`` axis
(logical "cache_seq"); the softmax over the sharded axis is expressed
as plain jnp reductions, which GSPMD turns into the flash-decoding
partial-max/sum all-reduce pattern.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.layers import PAb
from repro.dist.sharding import constrain
from repro.kernels.flash_attention import flash_attention


# ------------------------------------------------------------- GQA / MQA

def gqa_ab(cfg: ArchConfig):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = d ** -0.5
    return {
        "wq": PAb((d, H, hd), ("embed", "heads", None), "normal", s),
        "wk": PAb((d, Hkv, hd), ("embed", "kv", None), "normal", s),
        "wv": PAb((d, Hkv, hd), ("embed", "kv", None), "normal", s),
        "wo": PAb((H, hd, d), ("heads", None, "embed"), "normal",
                  (H * hd) ** -0.5),
    }


def gqa_train(cfg: ArchConfig, params, x, positions, mesh=None,
              causal: bool = True, kv_override=None, return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B,S,D)."""
    B, S, D = x.shape
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(cd))
    kv_src = x if kv_override is None else kv_override
    k = jnp.einsum("bsd,dhk->bhsk", kv_src, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bhsk", kv_src, params["wv"].astype(cd))
    if kv_override is None:  # self-attention: rotate q and k
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    if mesh is not None:
        # TP over heads when they divide the model axis; otherwise spread
        # the batch over model too (else attention replicates per device
        # and its quadratic buffers dominate the memory term — §Perf E1b)
        shardable = cfg.n_heads % mesh.shape.get("model", 1) == 0
        bax = "batch" if shardable else "attn_batch"
        q = constrain(q, mesh, (bax, "heads", "seq", None))
        k = constrain(k, mesh, (bax, "kv", "seq", None))
        v = constrain(v, mesh, (bax, "kv", "seq", None))
    out = flash_attention(q, k, v, causal=causal, window=cfg.window,
                          use_pallas=False)
    proj = jnp.einsum("bhsk,hkd->bsd", out, params["wo"].astype(cd))
    if mesh is not None:
        proj = constrain(proj, mesh, ("batch", "seq", None))
    if return_kv:
        return proj, (k, v)
    return proj


class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, Hkv, Smax, hd)
    v: jnp.ndarray


def gqa_init_cache(cfg: ArchConfig, batch, max_len, dtype):
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, max_len, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def gqa_cache_abstract(cfg: ArchConfig, batch, max_len, dtype):
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, max_len, hd)
    sd = jax.ShapeDtypeStruct(shape, dtype)
    return KVCache(k=sd, v=sd)


def gqa_cache_logical(cfg: ArchConfig):
    # shard kv heads over model when divisible; else shard the sequence
    # (flash-decoding style partial softmax — GSPMD inserts the combine)
    if cfg.n_kv_heads >= 16:
        ls = ("cache_batch", "kv", None, None)
    else:
        ls = ("cache_batch", None, "cache_seq", None)
    return KVCache(k=ls, v=ls)


def gqa_decode(cfg: ArchConfig, params, x, cache: KVCache, positions,
               mesh=None):
    """One-token decode. x: (B,1,D); positions: (B,1) absolute position."""
    B = x.shape[0]
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(cd))
    k_new = jnp.einsum("bsd,dhk->bhsk", x, params["wk"].astype(cd))
    v_new = jnp.einsum("bsd,dhk->bhsk", x, params["wv"].astype(cd))
    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k_new = L.apply_rope(k_new, positions, cfg.rope_theta, cfg.rope_fraction)

    # scatter the new kv at ``positions`` (same for all batch rows in this
    # framework: positions (B,1) with identical values per step)
    pos = positions[0, 0]
    z = jnp.zeros((), pos.dtype)
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (z, z, pos, z))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (z, z, pos, z))

    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    group = Hq // Hkv
    hd = cfg.resolved_head_dim
    Smax = k.shape[2]
    qg = q.reshape(B, Hkv, group, hd)
    scores = jnp.einsum("bhgk,bhsk->bhgs", qg,
                        k.astype(cd)) / jnp.sqrt(hd).astype(cd)
    idx = jnp.arange(Smax)
    mask = idx[None, :] <= pos
    if cfg.window is not None:
        mask &= idx[None, :] > pos - cfg.window
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cd)
    out = jnp.einsum("bhgs,bhsk->bhgk", w, v.astype(cd))
    out = out.reshape(B, Hq, 1, hd).swapaxes(1, 2)  # (B,1,H,hd)
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cd))
    return proj, KVCache(k=k, v=v)


# ---------------------------------------------------------------- MLA

def mla_ab(cfg: ArchConfig):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    s = d ** -0.5
    return {
        "wq_a": PAb((d, m.q_lora_rank), ("embed", "latent"), "normal", s),
        "q_norm": L.rmsnorm_ab(m.q_lora_rank),
        "wq_b": PAb((m.q_lora_rank, H, m.nope_dim + m.rope_dim),
                    ("latent", "heads", None), "normal", m.q_lora_rank ** -0.5),
        "wkv_a": PAb((d, m.kv_lora_rank + m.rope_dim), ("embed", "latent"),
                     "normal", s),
        "kv_norm": L.rmsnorm_ab(m.kv_lora_rank),
        "wk_b": PAb((m.kv_lora_rank, H, m.nope_dim), ("latent", "heads", None),
                    "normal", m.kv_lora_rank ** -0.5),
        "wv_b": PAb((m.kv_lora_rank, H, m.v_dim), ("latent", "heads", None),
                    "normal", m.kv_lora_rank ** -0.5),
        "wo": PAb((H, m.v_dim, d), ("heads", None, "embed"), "normal",
                  (H * m.v_dim) ** -0.5),
    }


def _mla_qk(cfg, params, x, positions):
    """Shared q / latent projections. Returns q_nope, q_rope, c_kv, k_rope."""
    m = cfg.mla
    cd = x.dtype
    ql = L.rmsnorm(params["q_norm"], x @ params["wq_a"].astype(cd),
                   cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bhsk", ql, params["wq_b"].astype(cd))
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"].astype(cd)                  # (B,S,rank+rope)
    c_kv = L.rmsnorm(params["kv_norm"], kv[..., : m.kv_lora_rank],
                     cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, None]           # (B,1,S,rope)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_train(cfg: ArchConfig, params, x, positions, mesh=None,
              return_latent: bool = False):
    """Full-sequence MLA (train / prefill): expand k,v from the latent."""
    m = cfg.mla
    cd = x.dtype
    q_nope, q_rope, c_kv, k_rope = _mla_qk(cfg, params, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bhsk", c_kv, params["wk_b"].astype(cd))
    v = jnp.einsum("bsr,rhk->bhsk", c_kv, params["wv_b"].astype(cd))
    k_rope_b = jnp.broadcast_to(
        k_rope, (*k_nope.shape[:-1], m.rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    if mesh is not None:
        q = constrain(q, mesh, ("batch", "heads", "seq", None))
        k = constrain(k, mesh, ("batch", "heads", "seq", None))
        v = constrain(v, mesh, ("batch", "heads", "seq", None))
    out = flash_attention(q, k, v, causal=True, use_pallas=False)
    proj = jnp.einsum("bhsk,hkd->bsd", out, params["wo"].astype(cd))
    if return_latent:
        return proj, (c_kv, k_rope[:, 0])       # (B,S,rank), (B,S,rope)
    return proj


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, Smax, kv_lora_rank)
    k_rope: jnp.ndarray  # (B, Smax, rope_dim)


def mla_init_cache(cfg, batch, max_len, dtype):
    m = cfg.mla
    return MLACache(c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                    k_rope=jnp.zeros((batch, max_len, m.rope_dim), dtype))


def mla_cache_abstract(cfg, batch, max_len, dtype):
    m = cfg.mla
    return MLACache(
        c_kv=jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jax.ShapeDtypeStruct((batch, max_len, m.rope_dim), dtype))


def mla_cache_logical(cfg):
    # the latent cache has no head dim: shard the sequence over model
    return MLACache(c_kv=("cache_batch", "cache_seq", None),
                    k_rope=("cache_batch", "cache_seq", None))


def mla_decode(cfg: ArchConfig, params, x, cache: MLACache, positions,
               mesh=None):
    """Absorbed-matmul decode: scores computed against the latent cache
    directly (q~ = q_nope @ W_kb per head), so per step the cache read is
    O(S * (rank + rope)) instead of O(S * H * head_dim)."""
    m = cfg.mla
    B = x.shape[0]
    cd = x.dtype
    q_nope, q_rope, c_new, kr_new = _mla_qk(cfg, params, x, positions)
    pos = positions[0, 0]
    z = jnp.zeros((), pos.dtype)
    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (z, pos, z))
    k_rope = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_new[:, 0].astype(cache.k_rope.dtype), (z, pos, z))

    # absorb: q~_h = q_nope_h @ W_kb_h^T  -> (B,H,1,rank)
    q_lat = jnp.einsum("bhsk,rhk->bhsr", q_nope, params["wk_b"].astype(cd))
    s_nope = jnp.einsum("bhsr,btr->bhst", q_lat, c_kv.astype(cd))
    s_rope = jnp.einsum("bhsk,btk->bhst", q_rope, k_rope.astype(cd))
    scale = 1.0 / jnp.sqrt(m.nope_dim + m.rope_dim).astype(jnp.float32)
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    idx = jnp.arange(c_kv.shape[1])
    scores = jnp.where((idx <= pos)[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cd)
    # attend in latent space, then expand once: (B,H,1,rank) @ W_vb
    o_lat = jnp.einsum("bhst,btr->bhsr", w, c_kv.astype(cd))
    out = jnp.einsum("bhsr,rhk->bhsk", o_lat, params["wv_b"].astype(cd))
    proj = jnp.einsum("bhsk,hkd->bsd", out, params["wo"].astype(cd))
    return proj, MLACache(c_kv=c_kv, k_rope=k_rope)
