"""Unified architecture config covering all assigned families:
dense / moe / ssm / hybrid (mamba+attn) / encdec (audio) / vlm."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    n_shared: int = 0           # always-on shared experts (deepseek)
    every: int = 1              # MoE layer every N layers (1 = all)
    first_dense: int = 0        # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    router_scale: bool = True   # normalize top-k weights to sum 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_dim: int = 64          # per-head rotary sub-dim (shared key)
    nope_dim: int = 128         # per-head non-rotary q/k sub-dim
    v_dim: int = 128            # per-head value dim


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # attention flavour
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # chatglm3 rotates half the head dim ("2d")
    window: Optional[int] = None          # SWA (mixtral)
    mla: Optional[MLAConfig] = None       # deepseek
    # FFN flavour
    act: str = "silu"           # silu|gelu
    gated: bool = True          # SwiGLU / GeGLU
    moe: Optional[MoEConfig] = None
    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    hybrid_group: Tuple[str, ...] = ()    # e.g. 8-layer jamba group pattern
    # encoder-decoder (whisper) / vlm
    enc_layers: int = 0
    enc_seq: int = 1500          # encoded audio frames (stub output length)
    vis_seq: int = 256           # vision patch tokens (stub output length)
    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    norm: str = "rmsnorm"        # rmsnorm|layernorm (whisper)
    embed_scale: bool = False    # multiply embeddings by sqrt(d) (gemma)
    pos_embedding: str = "rope"  # rope|learned (whisper decoder)
    max_position: int = 32768 + 8  # learned-pos table size (whisper)
    mtp_depth: int = 0           # deepseek multi-token-prediction heads
    # capability flags for the shape grid
    sub_quadratic: bool = False  # can run long_500k decode
    has_decoder: bool = True     # encoder-only would be False
    # numerics / scaling knobs (overridable per run)
    params_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"          # full|none
    scan_layers: bool = True
    vocab_pad_to: int = 256      # pad embedding rows so vocab dim shards
                                 # over the model axis (perf: §Perf E1)

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to <= 1:
            return self.vocab
        return -(-self.vocab // self.vocab_pad_to) * self.vocab_pad_to

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim

        def attn_params():
            if self.mla is not None:
                m = self.mla
                qk = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (m.nope_dim + m.rope_dim)
                kv = d * (m.kv_lora_rank + m.rope_dim) + m.kv_lora_rank * self.n_heads * (m.nope_dim + m.v_dim)
                o = self.n_heads * m.v_dim * d
                return qk + kv + o
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def mlp_params(ff):
            return d * ff * (3 if self.gated else 2)

        def moe_params():
            m = self.moe
            return (m.n_experts + m.n_shared) * mlp_params(m.d_expert) / mlp_params(f) * mlp_params(f) + d * m.n_experts

        def ssm_params():
            s = self.ssm
            di = s.expand * d
            conv_dim = di + 2 * s.n_groups * s.d_state
            nh = di // s.head_dim
            return (d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                    + conv_dim * s.d_conv + 2 * nh + di + di * d)

        total = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            total += self.n_layers * (ssm_params() + d)
            return int(total)
        if self.family == "hybrid":
            per_group = 0
            for kind in self.hybrid_group:
                blk = ssm_params() if kind == "m" else attn_params()
                per_group += blk + d
            # MoE every other layer in the group
            g = len(self.hybrid_group)
            n_moe = g // 2
            n_dense = g - n_moe
            per_group += n_moe * (self.moe.n_experts * mlp_params(self.moe.d_expert) + d * self.moe.n_experts)
            per_group += n_dense * mlp_params(f)
            per_group += g * d
            return int(total + (self.n_layers // g) * per_group)
        per_layer = attn_params() + 2 * d
        if self.moe is not None:
            m = self.moe
            n_moe_layers = max((self.n_layers - m.first_dense) // m.every, 0)
            n_dense_layers = self.n_layers - n_moe_layers
            per_moe = ((m.n_experts + m.n_shared) * mlp_params(m.d_expert)
                       + d * m.n_experts)
            total += n_moe_layers * (attn_params() + 2 * d + per_moe)
            total += n_dense_layers * (attn_params() + 2 * d + mlp_params(f))
        else:
            total += self.n_layers * (per_layer + mlp_params(f))
        if self.enc_layers:
            enc_per = attn_params() + mlp_params(f) + 2 * d
            dec_cross = attn_params() + d
            total += self.enc_layers * enc_per + self.n_layers * dec_cross
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        m = self.moe

        def mlp_params(ff):
            return d * ff * (3 if self.gated else 2)

        full = self.n_params()
        if self.family == "hybrid":
            g = len(self.hybrid_group)
            n_moe_layers = (self.n_layers // g) * (g // 2)
        else:
            n_moe_layers = max((self.n_layers - m.first_dense) // m.every, 0)
        inactive = n_moe_layers * (m.n_experts - m.top_k) * mlp_params(m.d_expert)
        return int(full - inactive)
