"""Shared layers for the LM stack: abstract params, norms, RoPE, MLPs,
embeddings, chunked cross-entropy.

Parameter system: every layer declares an *abstract* tree of
``PAb(shape, logical, init, scale)``.  From one abstract tree we derive
  * materialized params   (init_tree)      — training
  * PartitionSpecs        (spec_tree)      — GSPMD in/out shardings
  * ShapeDtypeStructs     (shape_tree)     — the dry-run (no allocation)
so sharding and shapes can never drift apart.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.sharding import (logical_to_mesh, resolve_spec, AxisRules,
                                 DEFAULT_RULES)


class PAb(NamedTuple):
    shape: tuple
    logical: tuple
    init: str = "normal"      # normal | zeros | ones
    scale: float = 1.0


def is_pab(x):
    return isinstance(x, PAb)


def init_tree(tree, key, dtype):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pab)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, ab in zip(keys, leaves):
        if ab.init == "zeros":
            out.append(jnp.zeros(ab.shape, dtype))
        elif ab.init == "ones":
            out.append(jnp.ones(ab.shape, dtype))
        else:
            out.append(ab.scale * jax.random.normal(k, ab.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def spec_tree(tree, mesh, rules: AxisRules = None):
    from repro.dist.sharding import active_rules
    rules = rules or active_rules()
    return jax.tree.map(
        lambda ab: jax.sharding.NamedSharding(
            mesh, resolve_spec(ab.shape, ab.logical, mesh, rules)),
        tree, is_leaf=is_pab)


def pspec_tree(tree, mesh, rules: AxisRules = None):
    from repro.dist.sharding import active_rules
    rules = rules or active_rules()
    return jax.tree.map(
        lambda ab: resolve_spec(ab.shape, ab.logical, mesh, rules),
        tree, is_leaf=is_pab)


def shape_tree(tree, dtype):
    return jax.tree.map(
        lambda ab: jax.ShapeDtypeStruct(ab.shape, dtype),
        tree, is_leaf=is_pab)


def count_params(tree) -> int:
    return sum(int(np.prod(ab.shape))
               for ab in jax.tree.leaves(tree, is_leaf=is_pab))


# ------------------------------------------------------------------ norms

def rmsnorm_ab(d):
    return {"scale": PAb((d,), ("embed",), "ones")}


def rmsnorm(params, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return out * params["scale"].astype(x.dtype)


def layernorm_ab(d):
    return {"scale": PAb((d,), ("embed",), "ones"),
            "bias": PAb((d,), ("embed",), "zeros")}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return out * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)


# ------------------------------------------------------------------- RoPE

def rope_angles(positions, dim, theta=10000.0):
    """positions (...,) -> (cos, sin) of shape (..., dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta=10000.0, fraction=1.0):
    """x: (B, H, S, D); rotate the first ``fraction`` of D (interleaved
    halves convention).  fraction=0.5 gives chatglm3's 2d-RoPE layout."""
    D = x.shape[-1]
    rot = int(D * fraction)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = rope_angles(positions, rot, theta)          # (B,S,rot/2)
    cos = cos[:, None, :, :]
    sin = sin[:, None, :, :]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1)


# -------------------------------------------------------------------- MLP

def mlp_ab(d, f, gated=True):
    s_in = d ** -0.5
    s_out = f ** -0.5
    p = {"up": PAb((d, f), ("embed", "mlp"), "normal", s_in),
         "down": PAb((f, d), ("mlp", "embed"), "normal", s_out)}
    if gated:
        p["gate"] = PAb((d, f), ("embed", "mlp"), "normal", s_in)
    return p


def mlp(params, x, act="silu", gated=True):
    actf = jax.nn.silu if act == "silu" else (
        lambda z: jax.nn.gelu(z, approximate=True))
    h = x @ params["up"].astype(x.dtype)
    if gated:
        h = actf(x @ params["gate"].astype(x.dtype)) * h
    else:
        h = actf(h)
    return h @ params["down"].astype(x.dtype)


# -------------------------------------------------- embeddings & loss

def embedding_ab(vocab, d, pad_to: int = 1):
    """pad_to > 1 rounds the vocab row count up so the vocab dim divides
    the model axis (otherwise logits replicate — §Perf E1).  Padded rows
    are masked out of the softmax in chunked_xent."""
    if pad_to > 1:
        vocab = -(-vocab // pad_to) * pad_to
    return {"table": PAb((vocab, d), ("vocab", "embed"), "normal", 1.0)}


def embed(params, tokens, scale_by_dim=True):
    tab = params["table"]
    out = tab[tokens]
    if scale_by_dim:
        out = out * (tab.shape[1] ** 0.5)
    return out


def unembed_logits(params, x, real_vocab: Optional[int] = None):
    """x: (B,S,D) -> (B,S,V_pad) logits with the tied table; padded
    vocab rows masked to -inf so sampling can never pick them."""
    tab = params["table"]
    logits = x @ tab.T.astype(x.dtype)
    if real_vocab is not None and real_vocab < tab.shape[0]:
        logits = logits + ((jnp.arange(tab.shape[0]) >= real_vocab)
                           * jnp.asarray(-1e30, logits.dtype))
    return logits


def chunked_xent(params, x, labels, chunk: int = 512,
                 real_vocab: Optional[int] = None):
    """Cross-entropy without materializing full (B,S,V) logits.

    Scans over sequence chunks; per chunk only (B,chunk,V) exists.
    Returns mean nll over tokens (label -100 = masked).  Padded vocab
    rows (>= real_vocab) are excluded from the softmax."""
    tab = params["table"]
    B, S, D = x.shape
    V = tab.shape[0]
    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks
    pad_mask = None
    if real_vocab is not None and real_vocab < V:
        pad_mask = (jnp.arange(V) >= real_vocab) * (-1e30)

    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)       # (nc,B,c,D)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xl):
        tot, cnt = carry
        xch, lch = xl
        logits = (xch @ tab.T.astype(xch.dtype)).astype(jnp.float32)
        if pad_mask is not None:
            logits = logits + pad_mask
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lch, 0)[..., None], axis=-1)[..., 0]
        mask = (lch >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)
