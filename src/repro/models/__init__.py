from repro.models.config import ArchConfig, MoEConfig, MLAConfig, SSMConfig
from repro.models import model, layers, attention, moe, mamba2

__all__ = ["ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "model", "layers", "attention", "moe", "mamba2"]
